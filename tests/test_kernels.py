"""Per-kernel allclose vs the ref.py jnp oracles, swept over shapes/dtypes
(interpret=True executes the kernel bodies on CPU), plus hypothesis
properties on the OTA update."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare interpreter: only the property tests skip
    from _hypothesis_stub import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ota_channel import ota_channel_apply
from repro.kernels.ssd_scan import ssd_scan


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,hkv,s,d,causal,window",
    [
        (1, 2, 2, 128, 64, True, None),
        (2, 4, 2, 256, 64, True, None),      # GQA g=2
        (1, 8, 2, 256, 128, True, None),     # GQA g=4
        (1, 2, 1, 256, 64, True, 128),       # sliding window
        (2, 2, 2, 384, 64, False, None),     # bidirectional (encoder)
        (1, 3, 1, 128, 112, True, None),     # zamba2 head_dim=112
    ],
)
def test_flash_attention_sweep(b, h, hkv, s, d, causal, window, dtype):
    ks = jax.random.split(jax.random.key(b * s + h + d), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=128, block_k=128)
    expected = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 3e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expected, np.float32),
        atol=tol, rtol=tol,
    )


def test_flash_attention_block_shape_invariance():
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (1, 2, 512, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 512, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 512, 64), jnp.float32)
    o1 = flash_attention(q, k, v, block_q=128, block_k=128)
    o2 = flash_attention(q, k, v, block_q=256, block_k=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5,
                               rtol=2e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,p,g,n,chunk",
    [
        (1, 128, 2, 64, 1, 64, 64),
        (2, 256, 4, 64, 1, 128, 128),        # mamba2-130m-like
        (1, 256, 4, 32, 2, 16, 64),          # grouped B/C
        (2, 128, 8, 64, 2, 64, 32),
    ],
)
def test_ssd_sweep(b, s, h, p, g, n, chunk, dtype):
    ks = jax.random.split(jax.random.key(s + h * p), 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32).astype(dtype)
    dt = (jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.1).astype(dtype)
    A = -jnp.exp(jax.random.uniform(ks[2], (h,), minval=0.0, maxval=1.0))
    B = (jax.random.normal(ks[3], (b, s, g, n)) * 0.5).astype(dtype)
    C = (jax.random.normal(ks[4], (b, s, g, n)) * 0.5).astype(dtype)
    out = ssd_scan(x, dt, A, B, C, chunk=chunk)
    expected = ref.ssd_ref(x, dt, A, B, C, chunk)
    tol = 5e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expected, np.float32),
        atol=tol, rtol=tol,
    )


def test_ssd_chunk_invariance_and_sequential_truth():
    ks = jax.random.split(jax.random.key(11), 5)
    b, s, h, p, g, n = 1, 256, 2, 32, 1, 32
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.1
    A = -jnp.exp(jax.random.uniform(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, g, n)) * 0.5
    o64 = ssd_scan(x, dt, A, B, C, chunk=64)
    o128 = ssd_scan(x, dt, A, B, C, chunk=128)
    seq = ref.ssd_sequential_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(o64), np.asarray(o128), atol=1e-4)
    np.testing.assert_allclose(np.asarray(o64), np.asarray(seq), atol=1e-4)


# ---------------------------------------------------------------------------
# OTA channel update
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(17,), (100, 37), (3, 5, 129)])
def test_ota_noiseless_exact(shape, dtype):
    v = jax.random.normal(jax.random.key(1), shape, jnp.float32).astype(dtype)
    out = ota_channel_apply(v, sigma=0.0, n_agents=7, m_h=1.2533)
    expected = (v.astype(jnp.float32) / (7 * 1.2533)).astype(dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32), rtol=1e-2,
                               atol=1e-6)


def test_ota_noise_statistics():
    v = jnp.zeros((512, 512), jnp.float32)
    out = ota_channel_apply(v, sigma=1.0, n_agents=1, m_h=1.0, seed=5)
    flat = np.asarray(out).ravel()
    assert abs(flat.mean()) < 0.01
    assert abs(flat.std() - 1.0) < 0.01
    # tail sanity: P(|z|>3) ~ 0.27%
    assert 0.001 < (np.abs(flat) > 3).mean() < 0.006


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 1000),
    n_agents=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_ota_property_determinism_and_scale(n, n_agents, seed):
    v = jnp.arange(n, dtype=jnp.float32).reshape(n)
    a = ota_channel_apply(v, sigma=0.5, n_agents=n_agents, seed=seed)
    b = ota_channel_apply(v, sigma=0.5, n_agents=n_agents, seed=seed)
    assert bool(jnp.all(a == b))
    # recovering v: (out*N - noise) linearity check via sigma=0 path
    c = ota_channel_apply(v, sigma=0.0, n_agents=n_agents, seed=seed)
    np.testing.assert_allclose(np.asarray(c) * n_agents, np.asarray(v),
                               rtol=1e-5, atol=1e-5)


def _kernel_noise(shape, seed, block_rows=256):
    """The kernel's own AWGN stream, extracted through the kernel itself:
    v=0, sigma=1, N=1, m_h=1 makes the fused update return exactly the
    noise tensor (out = (0 + 1*n) / 1).  Feeding it back through the jnp
    oracle isolates the scale/add arithmetic for the parity check."""
    z = jnp.zeros(shape, jnp.float32)
    return ota_channel_apply(z, sigma=1.0, n_agents=1, m_h=1.0, seed=seed,
                             block_rows=block_rows)


@pytest.mark.parametrize("seed", [0, 123])
@pytest.mark.parametrize("n_agents,m_h,debias", [
    (1, 1.0, True),
    (7, 1.2533, True),     # the paper's Rayleigh m_h
    (4, 0.8, False),       # debias off: m_h must not be applied
])
@pytest.mark.parametrize("sigma", [0.0, 0.5, 2.0])
def test_ota_kernel_parity_vs_ref(sigma, n_agents, m_h, debias, seed):
    """ota_channel_apply == ref.ota_channel_ref on the kernel's own noise,
    across sigma/scale/seed cases (interpret mode on CPU).  Tolerance is one
    fused-multiply-add of slack: the oracle's XLA lowering may contract
    v + sigma*n where the kernel keeps separate ops."""
    shape = (37, 65)  # deliberately unaligned with the (rows, 128) tiling
    v = jax.random.normal(jax.random.key(seed + 1), shape, jnp.float32)
    noise = _kernel_noise(shape, seed)
    out = ota_channel_apply(v, sigma=sigma, n_agents=n_agents, m_h=m_h,
                            debias=debias, seed=seed)
    expected = ref.ota_channel_ref(v, noise, sigma=sigma, n_agents=n_agents,
                                   m_h=m_h, debias=debias)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=1e-6, atol=1e-7)


def test_ota_kernel_parity_bf16():
    """Parity holds through the bfloat16 cast (compute stays f32)."""
    shape = (129,)
    v = jax.random.normal(jax.random.key(9), shape, jnp.float32)
    noise = _kernel_noise(shape, seed=3)
    out = ota_channel_apply(v.astype(jnp.bfloat16), sigma=0.5, n_agents=3,
                            m_h=1.1, seed=3)
    expected = ref.ota_channel_ref(v.astype(jnp.bfloat16),
                                   noise.astype(jnp.bfloat16),
                                   sigma=0.5, n_agents=3, m_h=1.1)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_ota_kernel_block_shape_invariance():
    """The noise counter is keyed on the absolute element index, so the
    same seed must give bitwise-identical output for any block_rows."""
    v = jax.random.normal(jax.random.key(5), (70000,), jnp.float32)
    a = ota_channel_apply(v, sigma=0.7, n_agents=5, m_h=1.2, seed=11,
                          block_rows=64)
    b = ota_channel_apply(v, sigma=0.7, n_agents=5, m_h=1.2, seed=11,
                          block_rows=256)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # different seeds must decorrelate, not shift, the stream
    c = ota_channel_apply(v, sigma=0.7, n_agents=5, m_h=1.2, seed=12,
                          block_rows=64)
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_ops_dispatch_agreement():
    """ops.py: pallas and ref paths agree on the same inputs."""
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64))
    k = jax.random.normal(ks[1], (1, 1, 128, 64))
    v = jax.random.normal(ks[2], (1, 1, 128, 64))
    a = ops.attention(q, k, v, use_pallas=True)
    b = ops.attention(q, k, v, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


# ---------------------------------------------------------------------------
# Fused OTA aggregation (ota_fused.py): gain matvec + AWGN + debias (+update)
# ---------------------------------------------------------------------------

from repro.kernels import ota_fused


def _grad_stack(key, n_agents, n_params):
    return jax.random.normal(key, (n_agents, n_params), jnp.float32)


def _fused_noise(n_params, seed, block_rows=None):
    """The fused kernel's AWGN stream, extracted through the kernel itself:
    zero gradients, one unit-gain agent, sigma=1, scale=1 make the aggregate
    return exactly the noise vector (u = (0 + 1*n) * 1)."""
    z = jnp.zeros((1, n_params), jnp.float32)
    return ota_fused.fused_aggregate(
        z, jnp.ones((1,), jnp.float32), sigma=1.0, scale=1.0, seed=seed,
        with_noise=True, block_rows=block_rows)


@pytest.mark.parametrize("seed", [0, 123])
@pytest.mark.parametrize("scale", [1.0, 1.0 / (7 * 1.2533)])
@pytest.mark.parametrize("sigma", [0.0, 0.5, 2.0])
@pytest.mark.parametrize("block_rows", [8, 64])
def test_fused_aggregate_parity_bitwise(sigma, scale, seed, block_rows):
    """fused_aggregate == ref.ota_fused_ref BITWISE in fp32: same matvec,
    same noise realisation (extracted from the kernel), same op order."""
    n_agents, n_params = 7, 1000   # deliberately unaligned with 128 lanes
    g = _grad_stack(jax.random.key(seed + 1), n_agents, n_params)
    h = jax.random.normal(jax.random.key(seed + 2), (n_agents,), jnp.float32)
    with_noise = sigma > 0.0
    noise = _fused_noise(n_params, seed, block_rows) if with_noise else None
    out = ota_fused.fused_aggregate(
        g, h, sigma=sigma, scale=scale, seed=seed, with_noise=with_noise,
        block_rows=block_rows)
    expected = ref.ota_fused_ref(g, h, noise, sigma=sigma, scale=scale)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expected))


@pytest.mark.parametrize("sigma", [0.0, 0.7])
def test_fused_sgd_parity(sigma):
    """sgd mode vs the oracle: one fused-multiply-add of slack — the
    kernel's interpret-mode p - alpha*u contracts into an FMA where the
    eager oracle keeps separate ops (tests/README.md tolerance policy)."""
    n_agents, n_params = 5, 777
    g = _grad_stack(jax.random.key(3), n_agents, n_params)
    h = jax.random.normal(jax.random.key(4), (n_agents,), jnp.float32)
    p = jax.random.normal(jax.random.key(5), (n_params,), jnp.float32)
    with_noise = sigma > 0.0
    noise = _fused_noise(n_params, 9) if with_noise else None
    out = ota_fused.fused_aggregate_sgd(
        g, h, p, alpha=0.05, sigma=sigma, scale=0.2, seed=9,
        with_noise=with_noise)
    expected = ref.ota_fused_sgd_ref(
        g, h, p, noise, alpha=0.05, sigma=sigma, scale=0.2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("step", [1, 10])
def test_fused_adam_parity(step):
    """adam mode vs the oracle (which mirrors optimizers._adam_core):
    bias-corrected moments and step, one-FMA slack in fp32."""
    n_agents, n_params = 4, 513
    g = _grad_stack(jax.random.key(6), n_agents, n_params)
    h = jnp.abs(jax.random.normal(jax.random.key(7), (n_agents,))) + 0.1
    ks = jax.random.split(jax.random.key(8), 3)
    p = jax.random.normal(ks[0], (n_params,), jnp.float32)
    mu = jax.random.normal(ks[1], (n_params,), jnp.float32) * 0.1
    nu = jnp.abs(jax.random.normal(ks[2], (n_params,))) * 0.01
    kw = dict(alpha=1e-3, step=step, b1=0.9, b2=0.999, eps=1e-8,
              sigma=0.4, scale=0.25)
    noise = _fused_noise(n_params, 21)
    outs = ota_fused.fused_aggregate_adam(g, h, p, mu, nu, seed=21,
                                          with_noise=True, **kw)
    refs = ref.ota_fused_adam_ref(g, h, p, mu, nu, noise, **kw)
    for a, b in zip(outs, refs):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_fused_adam_matches_optimizer_semantics():
    """The fused adam on a noiseless unit-gain single agent == applying
    repro.optim.optimizers.adam to the same (scaled) gradient."""
    from repro.optim.optimizers import adam

    n_params = 321
    g = jax.random.normal(jax.random.key(10), (1, n_params), jnp.float32)
    p = jax.random.normal(jax.random.key(11), (n_params,), jnp.float32)
    opt = adam(1e-3)
    state = opt.init(p)
    upd, state = opt.update(g[0], state)
    expected = p + upd
    out_p, _, _ = ota_fused.fused_aggregate_adam(
        g, jnp.ones((1,)), p, jnp.zeros_like(p), jnp.zeros_like(p),
        alpha=1e-3, step=1, with_noise=False)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(expected),
                               rtol=2e-6, atol=2e-7)


def test_fused_block_shape_invariance_and_seed_decorrelation():
    """Noise is keyed on the absolute flat element index: any block_rows
    gives bitwise-identical output; different seeds decorrelate."""
    n_agents, n_params = 3, 70000
    g = _grad_stack(jax.random.key(12), n_agents, n_params)
    h = jax.random.normal(jax.random.key(13), (n_agents,), jnp.float32)
    kw = dict(sigma=0.7, scale=0.1, with_noise=True)
    a = ota_fused.fused_aggregate(g, h, seed=11, block_rows=16, **kw)
    b = ota_fused.fused_aggregate(g, h, seed=11, block_rows=128, **kw)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = ota_fused.fused_aggregate(g, h, seed=12, block_rows=16, **kw)
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_fused_bf16_wire_tolerance():
    """bf16 wire format: payload narrowed, accumulation f32.  Documented
    tolerance ~1e-2 relative (tests/README.md) vs the f32 wire result."""
    n_agents, n_params = 8, 4096
    g = _grad_stack(jax.random.key(14), n_agents, n_params) * 1e-2
    h = jax.random.normal(jax.random.key(15), (n_agents,), jnp.float32)
    f32 = ota_fused.fused_aggregate(g, h, scale=0.125, with_noise=False)
    bf16 = ota_fused.fused_aggregate(g, h, scale=0.125, with_noise=False,
                                     wire_dtype=jnp.bfloat16)
    np.testing.assert_allclose(np.asarray(bf16), np.asarray(f32),
                               rtol=2e-2, atol=1e-4)
    assert not np.array_equal(np.asarray(bf16), np.asarray(f32))


def test_fused_vmap_folds_lanes_into_grid():
    """Sweep-lane batching: vmap over per-lane (sigma, scale, seed) equals
    the per-lane loop bitwise — the Pallas batching rule folds the lane
    axis into the kernel grid."""
    n_agents, n_params, lanes = 4, 800, 3
    g = _grad_stack(jax.random.key(16), n_agents, n_params)
    h = jax.random.normal(jax.random.key(17), (n_agents,), jnp.float32)
    sigmas = jnp.array([0.1, 0.5, 1.5], jnp.float32)
    scales = jnp.array([1.0, 0.25, 0.05], jnp.float32)
    seeds = jnp.arange(lanes, dtype=jnp.uint32)

    def one(sigma, scale, seed):
        return ota_fused.fused_aggregate(
            g, h, sigma=sigma, scale=scale, seed=seed, with_noise=True,
            block_rows=8)

    batched = jax.vmap(one)(sigmas, scales, seeds)
    looped = jnp.stack([one(sigmas[i], scales[i], seeds[i])
                        for i in range(lanes)])
    np.testing.assert_array_equal(np.asarray(batched), np.asarray(looped))


def test_ops_fused_dispatch_agreement():
    """ops.ota_aggregate: pallas and ref paths agree given the same noise
    (noiseless here; the noisy streams differ by design)."""
    g = _grad_stack(jax.random.key(18), 6, 500)
    h = jax.random.normal(jax.random.key(19), (6,), jnp.float32)
    a = ops.ota_aggregate(g, h, scale=0.2, with_noise=False, use_pallas=True)
    b = ops.ota_aggregate(g, h, scale=0.2, with_noise=False, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
