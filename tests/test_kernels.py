"""Per-kernel allclose vs the ref.py jnp oracles, swept over shapes/dtypes
(interpret=True executes the kernel bodies on CPU), plus hypothesis
properties on the OTA update."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare interpreter: only the property tests skip
    from _hypothesis_stub import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ota_channel import ota_channel_apply
from repro.kernels.ssd_scan import ssd_scan


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,hkv,s,d,causal,window",
    [
        (1, 2, 2, 128, 64, True, None),
        (2, 4, 2, 256, 64, True, None),      # GQA g=2
        (1, 8, 2, 256, 128, True, None),     # GQA g=4
        (1, 2, 1, 256, 64, True, 128),       # sliding window
        (2, 2, 2, 384, 64, False, None),     # bidirectional (encoder)
        (1, 3, 1, 128, 112, True, None),     # zamba2 head_dim=112
    ],
)
def test_flash_attention_sweep(b, h, hkv, s, d, causal, window, dtype):
    ks = jax.random.split(jax.random.key(b * s + h + d), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=128, block_k=128)
    expected = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 3e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expected, np.float32),
        atol=tol, rtol=tol,
    )


def test_flash_attention_block_shape_invariance():
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (1, 2, 512, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 512, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 512, 64), jnp.float32)
    o1 = flash_attention(q, k, v, block_q=128, block_k=128)
    o2 = flash_attention(q, k, v, block_q=256, block_k=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5,
                               rtol=2e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,p,g,n,chunk",
    [
        (1, 128, 2, 64, 1, 64, 64),
        (2, 256, 4, 64, 1, 128, 128),        # mamba2-130m-like
        (1, 256, 4, 32, 2, 16, 64),          # grouped B/C
        (2, 128, 8, 64, 2, 64, 32),
    ],
)
def test_ssd_sweep(b, s, h, p, g, n, chunk, dtype):
    ks = jax.random.split(jax.random.key(s + h * p), 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32).astype(dtype)
    dt = (jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.1).astype(dtype)
    A = -jnp.exp(jax.random.uniform(ks[2], (h,), minval=0.0, maxval=1.0))
    B = (jax.random.normal(ks[3], (b, s, g, n)) * 0.5).astype(dtype)
    C = (jax.random.normal(ks[4], (b, s, g, n)) * 0.5).astype(dtype)
    out = ssd_scan(x, dt, A, B, C, chunk=chunk)
    expected = ref.ssd_ref(x, dt, A, B, C, chunk)
    tol = 5e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expected, np.float32),
        atol=tol, rtol=tol,
    )


def test_ssd_chunk_invariance_and_sequential_truth():
    ks = jax.random.split(jax.random.key(11), 5)
    b, s, h, p, g, n = 1, 256, 2, 32, 1, 32
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.1
    A = -jnp.exp(jax.random.uniform(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, g, n)) * 0.5
    o64 = ssd_scan(x, dt, A, B, C, chunk=64)
    o128 = ssd_scan(x, dt, A, B, C, chunk=128)
    seq = ref.ssd_sequential_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(o64), np.asarray(o128), atol=1e-4)
    np.testing.assert_allclose(np.asarray(o64), np.asarray(seq), atol=1e-4)


# ---------------------------------------------------------------------------
# OTA channel update
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(17,), (100, 37), (3, 5, 129)])
def test_ota_noiseless_exact(shape, dtype):
    v = jax.random.normal(jax.random.key(1), shape, jnp.float32).astype(dtype)
    out = ota_channel_apply(v, sigma=0.0, n_agents=7, m_h=1.2533)
    expected = (v.astype(jnp.float32) / (7 * 1.2533)).astype(dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32), rtol=1e-2,
                               atol=1e-6)


def test_ota_noise_statistics():
    v = jnp.zeros((512, 512), jnp.float32)
    out = ota_channel_apply(v, sigma=1.0, n_agents=1, m_h=1.0, seed=5)
    flat = np.asarray(out).ravel()
    assert abs(flat.mean()) < 0.01
    assert abs(flat.std() - 1.0) < 0.01
    # tail sanity: P(|z|>3) ~ 0.27%
    assert 0.001 < (np.abs(flat) > 3).mean() < 0.006


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 1000),
    n_agents=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_ota_property_determinism_and_scale(n, n_agents, seed):
    v = jnp.arange(n, dtype=jnp.float32).reshape(n)
    a = ota_channel_apply(v, sigma=0.5, n_agents=n_agents, seed=seed)
    b = ota_channel_apply(v, sigma=0.5, n_agents=n_agents, seed=seed)
    assert bool(jnp.all(a == b))
    # recovering v: (out*N - noise) linearity check via sigma=0 path
    c = ota_channel_apply(v, sigma=0.0, n_agents=n_agents, seed=seed)
    np.testing.assert_allclose(np.asarray(c) * n_agents, np.asarray(v),
                               rtol=1e-5, atol=1e-5)


def _kernel_noise(shape, seed, block_rows=256):
    """The kernel's own AWGN stream, extracted through the kernel itself:
    v=0, sigma=1, N=1, m_h=1 makes the fused update return exactly the
    noise tensor (out = (0 + 1*n) / 1).  Feeding it back through the jnp
    oracle isolates the scale/add arithmetic for the parity check."""
    z = jnp.zeros(shape, jnp.float32)
    return ota_channel_apply(z, sigma=1.0, n_agents=1, m_h=1.0, seed=seed,
                             block_rows=block_rows)


@pytest.mark.parametrize("seed", [0, 123])
@pytest.mark.parametrize("n_agents,m_h,debias", [
    (1, 1.0, True),
    (7, 1.2533, True),     # the paper's Rayleigh m_h
    (4, 0.8, False),       # debias off: m_h must not be applied
])
@pytest.mark.parametrize("sigma", [0.0, 0.5, 2.0])
def test_ota_kernel_parity_vs_ref(sigma, n_agents, m_h, debias, seed):
    """ota_channel_apply == ref.ota_channel_ref on the kernel's own noise,
    across sigma/scale/seed cases (interpret mode on CPU).  Tolerance is one
    fused-multiply-add of slack: the oracle's XLA lowering may contract
    v + sigma*n where the kernel keeps separate ops."""
    shape = (37, 65)  # deliberately unaligned with the (rows, 128) tiling
    v = jax.random.normal(jax.random.key(seed + 1), shape, jnp.float32)
    noise = _kernel_noise(shape, seed)
    out = ota_channel_apply(v, sigma=sigma, n_agents=n_agents, m_h=m_h,
                            debias=debias, seed=seed)
    expected = ref.ota_channel_ref(v, noise, sigma=sigma, n_agents=n_agents,
                                   m_h=m_h, debias=debias)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=1e-6, atol=1e-7)


def test_ota_kernel_parity_bf16():
    """Parity holds through the bfloat16 cast (compute stays f32)."""
    shape = (129,)
    v = jax.random.normal(jax.random.key(9), shape, jnp.float32)
    noise = _kernel_noise(shape, seed=3)
    out = ota_channel_apply(v.astype(jnp.bfloat16), sigma=0.5, n_agents=3,
                            m_h=1.1, seed=3)
    expected = ref.ota_channel_ref(v.astype(jnp.bfloat16),
                                   noise.astype(jnp.bfloat16),
                                   sigma=0.5, n_agents=3, m_h=1.1)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_ota_kernel_block_shape_invariance():
    """The noise counter is keyed on the absolute element index, so the
    same seed must give bitwise-identical output for any block_rows."""
    v = jax.random.normal(jax.random.key(5), (70000,), jnp.float32)
    a = ota_channel_apply(v, sigma=0.7, n_agents=5, m_h=1.2, seed=11,
                          block_rows=64)
    b = ota_channel_apply(v, sigma=0.7, n_agents=5, m_h=1.2, seed=11,
                          block_rows=256)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # different seeds must decorrelate, not shift, the stream
    c = ota_channel_apply(v, sigma=0.7, n_agents=5, m_h=1.2, seed=12,
                          block_rows=64)
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_ops_dispatch_agreement():
    """ops.py: pallas and ref paths agree on the same inputs."""
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64))
    k = jax.random.normal(ks[1], (1, 1, 128, 64))
    v = jax.random.normal(ks[2], (1, 1, 128, 64))
    a = ops.attention(q, k, v, use_pallas=True)
    b = ops.attention(q, k, v, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
