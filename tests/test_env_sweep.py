"""Env-axis sweeps: registry round-trip, one-compile-per-partition over env
families, and bit-identical lanes vs per-scenario ``fedpg.monte_carlo`` when
a continuous env parameter varies — the same exactness contract the channel
axis is held to in ``test_sweep.py``."""
import jax
import numpy as np
import pytest

from repro.core import event_triggered, fedpg
from repro.core.channel import RayleighChannel
from repro.core.event_triggered import ETConfig
from repro.core.sweep import (
    Scenario, grid, partition_scenarios, resolve_env_policy, sweep,
)
from repro.rl.env import LandmarkNav
from repro.rl.envs import (
    CliffWalk, LQRTask, MultiLandmarkNav, WindyLandmarkNav,
    batched_env_arrays, build_lane_env, env_kind, garnet,
    make_env, make_heterogeneous_env, register_env,
)

SMALL = dict(n_agents=3, batch_m=2, horizon=6, n_rounds=4, debias=True)


def _hist_equal(a, b) -> bool:
    return all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(a, b)
    )


# ---------------------------------------------------------------------------
# registry round-trip
# ---------------------------------------------------------------------------

def test_registry_round_trip():
    assert env_kind(LandmarkNav()) == "landmark"
    assert env_kind(WindyLandmarkNav()) == "windy"
    assert env_kind(MultiLandmarkNav(n_landmarks=4)) == "multilandmark:4"
    assert env_kind(CliffWalk(width=5, height=3)) == "cliffwalk:5x3"
    assert env_kind(LQRTask(dim=3)) == "lqr:3"
    assert env_kind(garnet(jax.random.key(0), 5, 2)) == "tabular:5x2"
    e = make_env("cliffwalk", width=7)
    assert isinstance(e, CliffWalk) and e.width == 7
    with pytest.raises(ValueError, match="unknown environment"):
        make_env("nope")
    with pytest.raises(ValueError, match="not in the registry"):
        env_kind(object())


def test_register_env_extension_point():
    import dataclasses as dc

    @dc.dataclass(frozen=True)
    class Custom(LandmarkNav):
        pull: float = 0.5

    register_env("custom_test_env", Custom)
    assert env_kind(Custom()) == "custom_test_env"
    kind, arrays = batched_env_arrays([Custom(pull=0.1), Custom(pull=0.9)])
    assert kind == "custom_test_env" and set(arrays) == {"pull"}
    lane = build_lane_env(kind, Custom(), {"pull": 0.9})
    assert isinstance(lane, Custom) and lane.pull == 0.9


def test_batched_env_arrays_contract():
    # only varying float fields pack; constants stay closed-over literals
    kind, arrays = batched_env_arrays(
        [WindyLandmarkNav(wind=0.0), WindyLandmarkNav(wind=0.1)])
    assert kind == "windy" and set(arrays) == {"wind"}
    np.testing.assert_allclose(arrays["wind"], [0.0, 0.1])
    # declared-float fields accept int literals (schema, not value, decides)
    kind, arrays = batched_env_arrays(
        [WindyLandmarkNav(wind=0), WindyLandmarkNav(wind=1)])
    np.testing.assert_allclose(arrays["wind"], [0.0, 1.0])
    with pytest.raises(ValueError, match="cannot batch"):
        batched_env_arrays([LandmarkNav(), WindyLandmarkNav()])
    # non-float (structural) fields may not vary inside one kind
    with pytest.raises(ValueError, match="structural"):
        batched_env_arrays([LandmarkNav(n_actions=5), LandmarkNav(n_actions=4)])
    # garnet tables stack through the tabular packer hook
    ms = [garnet(jax.random.key(i), 4, 2, branching=2) for i in range(2)]
    kind, arrays = batched_env_arrays(ms)
    assert kind == "tabular:4x2"
    assert arrays["P"].shape == (2, 4, 2, 4)
    assert arrays["l"].shape == (2, 4, 2)
    assert arrays["rho"].shape == (2, 4)


# ---------------------------------------------------------------------------
# partitioning + one compile per env-family partition
# ---------------------------------------------------------------------------

def test_env_family_is_structural():
    scens = grid(
        env=[WindyLandmarkNav(wind=0.0), WindyLandmarkNav(wind=0.1),
             CliffWalk(width=4, height=3)],
        channel=RayleighChannel(), **SMALL,
    )
    parts = partition_scenarios(scens)
    assert len(parts) == 2  # wind lanes batch; cliffwalk splits
    # structural env sizes split within a family
    scens = grid(env=[MultiLandmarkNav(n_landmarks=2),
                      MultiLandmarkNav(n_landmarks=3)], **SMALL)
    assert len(partition_scenarios(scens)) == 2
    # default-env scenarios and env-carrying scenarios don't mix
    scens = [Scenario(channel=None, **SMALL),
             Scenario(channel=None, env=LandmarkNav(), **SMALL)]
    assert len(partition_scenarios(scens)) == 2


def test_two_env_families_compile_once_each(compile_counter):
    env_a = WindyLandmarkNav(wind=0.05)
    env_b = CliffWalk(width=4, height=3, slip=0.1)
    scens = grid(env=[env_a, env_b], channel=RayleighChannel(),
                 noise_sigma=1e-3, **SMALL)
    key = jax.random.key(0)
    # eager helpers are pre-warmed by the compile_counter fixture
    fedpg.clear_compilation_cache()
    with compile_counter() as c_naive:
        naive = [
            fedpg.monte_carlo(*resolve_env_policy(s), s.fedpg_config(), key,
                              2, ota=s.ota_config())
            for s in scens
        ]
    with compile_counter() as c_sweep:
        res = sweep(None, None, scens, key, 2)
    assert res.n_partitions == 2
    for i in range(len(scens)):
        assert _hist_equal(naive[i], res.scenario_history(i)), scens[i]
    assert c_sweep.count <= c_naive.count, (c_sweep.count, c_naive.count)


# ---------------------------------------------------------------------------
# bit-identical lanes for a varying continuous env parameter
# ---------------------------------------------------------------------------

def test_env_param_axis_bitwise_vs_monte_carlo(compile_counter):
    """A wind axis batches into ONE program whose lanes equal the
    per-scenario path bit-for-bit under the same PRNG keys."""
    scens = grid(
        env=[WindyLandmarkNav(wind=w) for w in (0.0, 0.05, 0.1)],
        channel=RayleighChannel(), noise_sigma=1e-3, **SMALL,
    )
    key = jax.random.key(5)
    # per-shape eager helpers (f32 packing converts, result unstacking
    # slices) are pre-warmed by the compile_counter fixture
    fedpg.clear_compilation_cache()
    with compile_counter() as c_naive:
        naive = [
            fedpg.monte_carlo(*resolve_env_policy(s), s.fedpg_config(), key,
                              2, ota=s.ota_config())
            for s in scens
        ]
    with compile_counter() as c_sweep:
        res = sweep(None, None, scens, key, 2)
    assert res.n_partitions == 1
    assert c_sweep.count < c_naive.count, (c_sweep.count, c_naive.count)
    for i in range(len(scens)):
        assert _hist_equal(naive[i], res.scenario_history(i)), scens[i]


def test_garnet_table_lanes_bitwise(compile_counter):
    """Whole Garnet P/l/rho tables batch as lanes (array-valued packer)."""
    ms = [garnet(jax.random.key(i), 4, 2, branching=2) for i in range(3)]
    scens = grid(env=ms, channel=RayleighChannel(), **SMALL)
    key = jax.random.key(7)
    res = sweep(None, None, scens, key, 2)
    assert res.n_partitions == 1
    for i, s in enumerate(scens):
        ref = fedpg.monte_carlo(*resolve_env_policy(s), s.fedpg_config(), key,
                                2, ota=s.ota_config())
        assert _hist_equal(ref, res.scenario_history(i))
    # env identity lands in the result table
    rows = res.to_dicts(tail=2)
    assert rows[0]["env"] == "tabular:4x2"
    assert res.index(env=ms[1]) == 1


def test_default_env_scenarios_unchanged():
    """Scenarios without an env keep the pre-env-zoo behaviour: sweep's
    positional (env, policy) is used and lanes match monte_carlo."""
    env, pol = LandmarkNav(), LandmarkNav().default_policy()
    s = Scenario(channel=RayleighChannel(), **SMALL)
    key = jax.random.key(2)
    res = sweep(env, pol, [s], key, 2)
    ref = fedpg.monte_carlo(env, pol, s.fedpg_config(), key, 2,
                            ota=s.ota_config())
    assert _hist_equal(ref, res.scenario_history(0))
    assert res.to_dicts(tail=2)[0]["env"] == "default"
    with pytest.raises(ValueError, match="no env"):
        sweep(None, None, [s], key, 2)


def test_scenario_policy_override():
    from repro.rl.policy import MLPPolicy

    wide = MLPPolicy(obs_dim=4, hidden=8, n_actions=5)
    s = Scenario(env=LandmarkNav(), policy=wide, channel=None, **SMALL)
    assert resolve_env_policy(s)[1] is wide
    res = sweep(None, None, [s], jax.random.key(0), 2)
    assert res.to_dicts(tail=2)[0]["policy"] == "MLPPolicy"


def test_unhashable_policies_split_partitions():
    """Distinct unhashable policy instances must NOT merge into one
    partition (they would silently all run the prototype's policy)."""
    import dataclasses as dc

    import jax.numpy as jnp

    from repro.rl.policy import MLPPolicy

    @dc.dataclass(frozen=True)
    class BiasedMLP(MLPPolicy):
        # an array field makes the policy unhashable
        logit_bias: jnp.ndarray = None  # type: ignore[assignment]

        def logits(self, params, obs):
            return super().logits(params, obs) + self.logit_bias

    flat = BiasedMLP(logit_bias=jnp.zeros((5,)))
    skew = BiasedMLP(logit_bias=jnp.array([5.0, 0.0, 0.0, 0.0, -5.0]))
    scens = [Scenario(env=LandmarkNav(), policy=flat, channel=None, **SMALL),
             Scenario(env=LandmarkNav(), policy=skew, channel=None, **SMALL)]
    res = sweep(None, None, scens, jax.random.key(0), 2)
    assert res.n_partitions == 2
    assert not np.array_equal(np.asarray(res.history.rewards[0]),
                              np.asarray(res.history.rewards[1]))


# ---------------------------------------------------------------------------
# heterogeneous agents through fedpg / event_triggered / sweep
# ---------------------------------------------------------------------------

def test_heterogeneous_env_runs_in_sweep_and_fedpg():
    het = make_heterogeneous_env(
        [WindyLandmarkNav(wind=0.03 * i) for i in range(SMALL["n_agents"])]
    )
    s = Scenario(env=het, channel=RayleighChannel(), noise_sigma=1e-3, **SMALL)
    key = jax.random.key(3)
    res = sweep(None, None, [s], key, 2)
    ref = fedpg.monte_carlo(het, het.default_policy(), s.fedpg_config(), key,
                            2, ota=s.ota_config())
    assert _hist_equal(ref, res.scenario_history(0))
    assert res.to_dicts(tail=2)[0]["env"] == f"hetero:windy:{SMALL['n_agents']}"


def test_heterogeneous_dynamics_actually_differ_per_agent():
    """An extreme-wind fleet must behave differently from a calm plain env —
    the per-agent vmap really threads different dynamics."""
    calm = WindyLandmarkNav(wind=0.0, gust_sigma=0.0)
    fleet = make_heterogeneous_env(
        [calm, WindyLandmarkNav(wind=5.0, gust_sigma=0.0),
         WindyLandmarkNav(wind=-5.0, gust_sigma=0.0)]
    )
    cfg = fedpg.FedPGConfig(n_agents=3, batch_m=2, horizon=6, n_rounds=3)
    pol = calm.default_policy()
    key = jax.random.key(0)
    _, hist_fleet = fedpg.run(fleet, pol, cfg, key)
    _, hist_plain = fedpg.run(calm, pol, cfg, key)
    assert not np.allclose(np.asarray(hist_fleet.rewards),
                           np.asarray(hist_plain.rewards))
    # all-equal fleet == plain env, bit for bit (same lanes, shared consts)
    degenerate = make_heterogeneous_env([calm, calm, calm])
    _, hist_deg = fedpg.run(degenerate, pol, cfg, key)
    assert _hist_equal(hist_deg, hist_plain)


def test_heterogeneous_agent_count_guard_in_loops():
    het = make_heterogeneous_env([WindyLandmarkNav(wind=w) for w in (0.0, 0.1)])
    cfg = fedpg.FedPGConfig(n_agents=4, batch_m=2, horizon=4, n_rounds=2)
    pol = het.default_policy()
    with pytest.raises(ValueError, match="n_agents=2"):
        fedpg.run(het, pol, cfg, jax.random.key(0))
    with pytest.raises(ValueError, match="n_agents=2"):
        event_triggered.run(het, pol, cfg, ETConfig(), jax.random.key(0))
    with pytest.raises(ValueError, match="n_agents=2"):
        sweep(None, None,
              [Scenario(env=het, channel=None, n_agents=4, batch_m=2,
                        horizon=4, n_rounds=2)],
              jax.random.key(0), 2)


def test_two_fleets_batch_as_lanes():
    """Two same-shape HeterogeneousEnv fleets (mild vs extreme per-agent
    winds) share one partition and batch through the hetero packer; each
    lane matches running that fleet directly."""
    n = SMALL["n_agents"]
    mild = make_heterogeneous_env([WindyLandmarkNav(wind=0.01 * i)
                                   for i in range(n)])
    wild = make_heterogeneous_env([WindyLandmarkNav(wind=0.05 * i)
                                   for i in range(n)])
    scens = grid(env=[mild, wild], channel=RayleighChannel(), **SMALL)
    key = jax.random.key(6)
    res = sweep(None, None, scens, key, 2)
    assert res.n_partitions == 1
    for i, fleet in enumerate((mild, wild)):
        ref = fedpg.monte_carlo(fleet, fleet.default_policy(),
                                scens[i].fedpg_config(), key, 2,
                                ota=scens[i].ota_config())
        assert _hist_equal(ref, res.scenario_history(i))
    # fleets stacking different field sets are a clear error, not a crash
    # (same base as `mild`: first member is the all-defaults wind=0.0 env)
    odd = make_heterogeneous_env(
        [WindyLandmarkNav(wind=0.0, gust_sigma=0.02 * (i + 1))
         for i in range(n)])
    with pytest.raises(ValueError, match="different .*fields"):
        sweep(None, None, grid(env=[mild, odd], channel=RayleighChannel(),
                               **SMALL), key, 2)
    # and so are fleets whose bases differ in a NON-stacked field
    shifted = make_heterogeneous_env(
        [WindyLandmarkNav(wind=0.01 * i, arena=2.0) for i in range(n)])
    with pytest.raises(ValueError, match="non-stacked field"):
        sweep(None, None, grid(env=[mild, shifted], channel=RayleighChannel(),
                               **SMALL), key, 2)


def test_fleets_differing_only_in_stacked_fields_batch():
    """Base values of stacked fields are irrelevant (always overridden per
    agent), so fleets whose *first members* differ in a stacked field must
    still batch."""
    n = SMALL["n_agents"]
    a = make_heterogeneous_env([WindyLandmarkNav(wind=0.01 * (i + 1))
                                for i in range(n)])
    b = make_heterogeneous_env([WindyLandmarkNav(wind=0.04 * (i + 1))
                                for i in range(n)])
    key = jax.random.key(8)
    scens = grid(env=[a, b], channel=None, **SMALL)
    res = sweep(None, None, scens, key, 2)
    assert res.n_partitions == 1
    for i, fleet in enumerate((a, b)):
        ref = fedpg.monte_carlo(fleet, fleet.default_policy(),
                                scens[i].fedpg_config(), key, 2, ota=None)
        assert _hist_equal(ref, res.scenario_history(i))


def test_identity_distinct_equal_fleets_share_one_lane():
    """Two separately-built all-equal fleets pack to zero varying fields;
    the partition must take the replicate-one-lane path, not crash on a
    zero-leaf vmap."""
    n = SMALL["n_agents"]
    calm = WindyLandmarkNav(wind=0.0, gust_sigma=0.0)
    f1 = make_heterogeneous_env([calm] * n)
    f2 = make_heterogeneous_env([calm] * n)
    res = sweep(None, None, grid(env=[f1, f2], channel=None, **SMALL),
                jax.random.key(9), 2)
    assert res.n_partitions == 1
    assert _hist_equal(res.scenario_history(0), res.scenario_history(1))


def test_event_triggered_heterogeneous():
    het = make_heterogeneous_env(
        [WindyLandmarkNav(wind=0.05 * i) for i in range(3)]
    )
    cfg = fedpg.FedPGConfig(n_agents=3, batch_m=2, horizon=5, n_rounds=3)
    _, hist = event_triggered.run(het, het.default_policy(), cfg, ETConfig(),
                                  jax.random.key(0))
    assert hist.rewards.shape == (3,)
    assert bool(np.all(np.isfinite(np.asarray(hist.rewards))))
    assert float(np.max(np.asarray(hist.uploads))) <= 3


# ---------------------------------------------------------------------------
# LQR (continuous actions) through the engine
# ---------------------------------------------------------------------------

def test_lqr_scenario_through_sweep():
    """LQR lanes batch like any family; its matvec/quadratic-loss fusions
    may reassociate when a traced parameter is present, so (documented in
    the sweep module) equality is to the last-bit tolerance rather than
    bitwise — unlike the elementwise-dynamics families above."""
    scens = grid(env=[LQRTask(process_sigma=0.0), LQRTask(process_sigma=0.1)],
                 channel=None, **SMALL)
    key = jax.random.key(4)
    res = sweep(None, None, scens, key, 2)
    assert res.n_partitions == 1  # process_sigma is a lane parameter
    for i, s in enumerate(scens):
        ref = fedpg.monte_carlo(*resolve_env_policy(s), s.fedpg_config(), key,
                                2, ota=s.ota_config())
        got = res.scenario_history(i)
        for a, b in zip(ref, got):
            if a is None and b is None:  # telemetry off on both sides
                continue
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6)
