"""The OTA aggregation primitive: the three implementations must agree, the
estimator must be (conditionally) unbiased after m_h debiasing, and the
noiseless/unit-gain configuration must reduce exactly to Algorithm 1."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare interpreter: only the property tests skip
    from _hypothesis_stub import given, settings, st

from repro.core import ota
from repro.core.channel import FixedGainChannel, IdealChannel, RayleighChannel
from repro.utils.tree import tree_global_norm, tree_sub


def _grads(key, n_agents, shapes=((3, 4), (5,), (2, 2, 2))):
    ks = jax.random.split(key, len(shapes))
    return {
        f"w{i}": jax.random.normal(k, (n_agents,) + s, jnp.float32)
        for i, (k, s) in enumerate(zip(ks, shapes))
    }


def test_ideal_channel_equals_exact_mean(key):
    g = _grads(key, 6)
    cfg = ota.OTAConfig(channel=IdealChannel(), noise_sigma=0.0)
    u, h = ota.aggregate_stacked(cfg, jax.random.key(1), g)
    exact = ota.exact_aggregate(g)
    for a, b in zip(jax.tree.leaves(u), jax.tree.leaves(exact)):
        np.testing.assert_allclose(a, b, rtol=1e-6)
    assert jnp.all(h == 1.0)


def test_fixed_gain_debias_recovers_mean(key):
    g = _grads(key, 4)
    cfg = ota.OTAConfig(channel=FixedGainChannel(gain=2.5), noise_sigma=0.0,
                        debias=True)
    u, _ = ota.aggregate_stacked(cfg, jax.random.key(1), g)
    exact = ota.exact_aggregate(g)
    for a, b in zip(jax.tree.leaves(u), jax.tree.leaves(exact)):
        # identity holds to float32 round-off; atol covers near-zero elements
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_unbiasedness_under_rayleigh(key):
    """E[v_k/(m_h N)] == mean gradient (Lemma 3's premise)."""
    n_agents = 4
    g = _grads(key, n_agents)
    cfg = ota.OTAConfig(channel=RayleighChannel(), noise_sigma=0.01, debias=True)

    @jax.jit
    def one(k):
        u, _ = ota.aggregate_stacked(cfg, k, g)
        return u

    n_rounds = 3000
    us = jax.vmap(one)(jax.random.split(jax.random.key(7), n_rounds))
    mean_u = jax.tree.map(lambda x: jnp.mean(x, 0), us)
    exact = ota.exact_aggregate(g)
    err = tree_global_norm(tree_sub(mean_u, exact))
    scale = tree_global_norm(exact)
    assert float(err / scale) < 0.05


def test_weighted_loss_equals_explicit_per_agent(key):
    """The channel-weighted-loss trick == explicit sum_i h_i grad_i / N."""
    n_agents, per = 4, 3
    w = jax.random.normal(key, (8,), jnp.float32)
    x = jax.random.normal(jax.random.key(3), (n_agents * per, 8), jnp.float32)
    y = jax.random.normal(jax.random.key(4), (n_agents * per,), jnp.float32)
    gains = jnp.array([0.3, 1.7, 0.9, 2.2], jnp.float32)

    def per_example_loss(w, xi, yi):
        return (xi @ w - yi) ** 2

    # explicit per-agent gradients
    def agent_grad(i):
        sl = slice(i * per, (i + 1) * per)
        return jax.grad(
            lambda w: jnp.mean(jax.vmap(per_example_loss, (None, 0, 0))(w, x[sl], y[sl]))
        )(w)

    explicit = sum(gains[i] * agent_grad(i) for i in range(n_agents)) / n_agents

    # weighted-loss trick
    ew = ota.example_weights(gains, n_agents * per)
    weighted = jax.grad(
        lambda w: jnp.mean(ew * jax.vmap(per_example_loss, (None, 0, 0))(w, x, y))
    )(w)
    np.testing.assert_allclose(np.asarray(weighted), np.asarray(explicit), rtol=1e-5)


def test_example_weights_shape_and_errors():
    gains = jnp.array([1.0, 2.0])
    w = ota.example_weights(gains, 6)
    np.testing.assert_allclose(np.asarray(w), [1, 1, 1, 2, 2, 2])
    with pytest.raises(ValueError):
        ota.example_weights(gains, 5)


def test_add_awgn_statistics(key):
    grad = {"w": jnp.zeros((100, 100), jnp.float32)}
    cfg = ota.OTAConfig(channel=IdealChannel(), noise_sigma=0.8, debias=False)
    out = ota.add_awgn(cfg, key, grad, n_agents=4)
    # noise std should be sigma / N
    assert float(jnp.std(out["w"])) == pytest.approx(0.8 / 4, rel=0.05)


@settings(max_examples=20, deadline=None)
@given(
    n_agents=st.integers(1, 8),
    sigma=st.floats(0.0, 1.0),
    gain=st.floats(0.1, 3.0),
)
def test_property_zero_grads_yield_pure_noise(n_agents, sigma, gain):
    """With g_i = 0: u = n_k / N exactly — the channel cannot invent signal."""
    g = {"w": jnp.zeros((n_agents, 16), jnp.float32)}
    cfg = ota.OTAConfig(
        channel=FixedGainChannel(gain=gain), noise_sigma=sigma, debias=False
    )
    u, _ = ota.aggregate_stacked(cfg, jax.random.key(0), g)
    if sigma == 0.0:
        assert float(jnp.max(jnp.abs(u["w"]))) == 0.0
    else:
        # replicate aggregate_stacked's key path: split -> key_n, then
        # tree_normal_like splits key_n once per leaf
        key_n = jax.random.split(jax.random.key(0))[1]
        leaf_key = jax.random.split(key_n, 1)[0]
        expected = jax.random.normal(leaf_key, (16,), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(u["w"]), np.asarray(expected) * sigma / n_agents,
            rtol=1e-4, atol=1e-6,
        )


def test_psum_aggregate_matches_stacked(key):
    """Form 2 (shard_map psum) == Form 1 (stacked) given the same gains."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    n = jax.local_device_count()
    if n < 2:
        pytest.skip("needs >=2 devices (run via tests/test_dryrun_subprocess)")
    mesh = jax.make_mesh((n,), ("data",))
    g = _grads(key, n)
    cfg = ota.OTAConfig(channel=RayleighChannel(), noise_sigma=0.1, debias=True)
    round_key = jax.random.key(5)

    def local(gl):
        return ota.psum_aggregate(cfg, round_key, gl, ("data",))

    out = shard_map(
        local, mesh=mesh, in_specs=({k: P("data") for k in g},),
        out_specs={k: P() for k in g}, check_rep=False,
    )(g)

    key_h, _ = jax.random.split(round_key)
    gains = jnp.stack(
        [cfg.channel.sample(jax.random.fold_in(key_h, i), ()) for i in range(n)]
    )
    ref, _ = ota.aggregate_stacked(cfg, round_key, g, gains=gains)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=1e-6)
