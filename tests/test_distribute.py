"""Sharded sweep execution over a device mesh.

The contract under test: ``sweep(..., mode="sharded")`` is **bit-identical**
to ``mode="vmap"`` — sharding moves data placement, never the per-lane
jaxpr — for every golden scenario (LQR at its documented rtol), with uneven
lane counts padded by masked replicate-lanes and partitions dispatched
asynchronously.  Plus the agent-axis hook: ``fedpg.run(..., agent_mesh=...)``
runs each round's fleet in the production shard_map/psum form.

Everything here passes on a single device (degenerate 1-device mesh); CI
additionally runs this file under an emulated 8-device mesh:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m pytest tests/test_distribute.py

which is also the recommended way to develop against it locally.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fedpg
from repro.core.channel import FixedGainChannel, RayleighChannel
from repro.core.distribute import (
    agent_mesh_for, default_sweep_mesh, dispatch_partition, pad_lanes,
    place_partition, plan_placement,
)
from repro.core.ota import (
    OTAConfig, aggregate_stacked, psum_aggregate, psum_aggregate_stacked,
)
from repro.core.power_control import HeterogeneousBudget
from repro.core.sweep import Scenario, grid, sweep
from repro.launch.mesh import make_agent_mesh, make_sweep_mesh
from repro.rl.env import LandmarkNav
from repro.rl.policy import MLPPolicy
from test_golden import RTOL, golden_cases, run_golden_sweep

N_DEV = jax.device_count()
SMALL = dict(n_agents=4, batch_m=3, horizon=8, n_rounds=5, debias=True)


@pytest.fixture(scope="module")
def env_pol():
    return LandmarkNav(), MLPPolicy()


def _hist_equal(a, b) -> bool:
    return all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(a, b)
    )


# ---------------------------------------------------------------------------
# mesh constructors + placement planning
# ---------------------------------------------------------------------------

def test_make_sweep_mesh_shapes():
    mesh = make_sweep_mesh()
    assert tuple(mesh.axis_names) == ("lane", "mc")
    assert mesh.shape["lane"] == N_DEV and mesh.shape["mc"] == 1
    assert mesh.size == N_DEV
    sub = make_sweep_mesh(lane_shards=1)
    assert sub.size == 1
    with pytest.raises(ValueError, match="devices"):
        make_sweep_mesh(lane_shards=N_DEV + 1, mc_shards=2)
    with pytest.raises(ValueError, match="mc_shards"):
        make_sweep_mesh(mc_shards=0)
    with pytest.raises(ValueError, match="lane_shards"):
        make_sweep_mesh(lane_shards=0)


def test_make_agent_mesh_and_agent_mesh_for():
    mesh = make_agent_mesh()
    assert tuple(mesh.axis_names) == ("agents",)
    assert mesh.size == N_DEV
    with pytest.raises(ValueError, match="out of range"):
        make_agent_mesh(N_DEV + 1)
    # agent_mesh_for picks the largest device count dividing n_agents
    for n_agents in (1, 2, 3, 4, 6, 8, 12):
        m = agent_mesh_for(n_agents)
        assert n_agents % m.size == 0
        assert m.size <= N_DEV
    assert agent_mesh_for(1).size == 1


def test_plan_placement():
    mesh = make_sweep_mesh()
    d = mesh.shape["lane"]
    # uneven lanes pad up to the lane axis
    p = plan_placement(mesh, n_lanes=d + 1 if d > 1 else 3, mc_runs=2)
    assert (p.n_lanes + p.n_pad) % d == 0
    assert p.n_devices == mesh.size
    # the replicate path shards MC over the whole mesh only when divisible
    p0 = plan_placement(mesh, n_lanes=0, mc_runs=mesh.size)
    if mesh.size > 1:
        assert p0.key_spec != jax.sharding.PartitionSpec()
    p1 = plan_placement(mesh, n_lanes=0, mc_runs=mesh.size + 1)
    assert p1.key_spec == jax.sharding.PartitionSpec()
    # meshes without a lane axis are rejected with guidance
    bad = make_agent_mesh(1)
    with pytest.raises(ValueError, match="lane"):
        plan_placement(bad, 4, 2)


def test_pad_lanes_replicates_last_lane():
    packed = {"a": jnp.arange(3.0), "b": {"c": jnp.arange(6.0).reshape(3, 2)}}
    padded = pad_lanes(packed, 2)
    assert padded["a"].shape == (5,)
    np.testing.assert_array_equal(np.asarray(padded["a"]), [0, 1, 2, 2, 2])
    np.testing.assert_array_equal(np.asarray(padded["b"]["c"][3:]),
                                  np.asarray(packed["b"]["c"][2:]).repeat(2, 0))
    assert pad_lanes(packed, 0) is packed


# ---------------------------------------------------------------------------
# the bit-identity contract: sharded == vmap
# ---------------------------------------------------------------------------

def test_sharded_matches_vmap_uneven_lanes(env_pol):
    """More lanes than divide the mesh (6 on most device counts): padding
    with masked replicate-lanes must not perturb a single real lane."""
    env, pol = env_pol
    scens = grid(channel=RayleighChannel(),
                 noise_sigma=[1e-3, 2e-3, 5e-3, 1e-2, 2e-2, 5e-2], **SMALL)
    key = jax.random.key(0)
    rv = sweep(env, pol, scens, key, 2, mode="vmap")
    rs = sweep(env, pol, scens, key, 2, mode="sharded")
    assert rs.mode == "sharded" and rs.n_devices == N_DEV
    assert rv.n_devices == 1
    for i in range(len(scens)):
        assert _hist_equal(rv.scenario_history(i), rs.scenario_history(i)), i


def test_sharded_matches_vmap_on_golden_scenarios():
    """The acceptance contract: every golden (env family x uplink) scenario
    is bit-identical between sharded and vmap execution — LQR within its
    documented rtol (see tests/test_golden.py)."""
    ref = run_golden_sweep("vmap")
    got = run_golden_sweep("sharded")
    assert set(ref) == set(got) and len(ref) == len(golden_cases())
    for (fam, uplink), h_ref in ref.items():
        h_got = got[(fam, uplink)]
        rtol = RTOL.get(fam)
        for name, a, b in zip(("rewards", "grad_sq", "gain_mean"),
                              h_ref, h_got):
            a, b = np.asarray(a), np.asarray(b)
            if rtol is None:
                assert np.array_equal(a, b), (fam, uplink, name)
            else:
                np.testing.assert_allclose(
                    a, b, rtol=rtol, atol=0.0,
                    err_msg=f"{fam}/{uplink}/{name}")


def test_sharded_replicate_path_and_mc_sharding(env_pol):
    """Identical scenarios pack to nothing: the replicate path shards the
    MC axis across the whole mesh and must still match vmap bitwise."""
    env, pol = env_pol
    s = Scenario(channel=RayleighChannel(), noise_sigma=1e-3, **SMALL)
    mc = max(N_DEV, 2)  # divisible by the mesh => keys shard
    key = jax.random.key(1)
    rv = sweep(env, pol, [s, s], key, mc, mode="vmap")
    rs = sweep(env, pol, [s, s], key, mc, mode="sharded")
    for i in range(2):
        assert _hist_equal(rv.scenario_history(i), rs.scenario_history(i))
    assert _hist_equal(rs.scenario_history(0), rs.scenario_history(1))


@pytest.mark.skipif(N_DEV < 2, reason="needs >=2 devices for an mc axis")
def test_sharded_lane_x_mc_mesh(env_pol):
    env, pol = env_pol
    scens = grid(channel=RayleighChannel(), noise_sigma=[1e-3, 1e-2], **SMALL)
    mesh = make_sweep_mesh(lane_shards=N_DEV // 2, mc_shards=2)
    key = jax.random.key(2)
    rv = sweep(env, pol, scens, key, 2, mode="vmap")
    rs = sweep(env, pol, scens, key, 2, mode="sharded", mesh=mesh)
    assert rs.n_devices == mesh.size
    for i in range(len(scens)):
        assert _hist_equal(rv.scenario_history(i), rs.scenario_history(i))


def test_sharded_mixed_partitions_async_accounting(env_pol):
    """Several structurally distinct partitions dispatch asynchronously;
    timing lands on every partition and scenario_time_us stays positive."""
    env, pol = env_pol
    scens = [Scenario(channel=RayleighChannel(), noise_sigma=1e-3, **SMALL),
             Scenario(channel=None, **SMALL),
             Scenario(channel=RayleighChannel(), noise_sigma=2e-3, **SMALL)]
    res = sweep(env, pol, scens, jax.random.key(3), 2, mode="sharded")
    assert res.n_partitions == 2
    assert all(p.wall_time_us > 0 for p in res.partitions)
    assert all(res.scenario_time_us(i) > 0 for i in range(len(scens)))
    ref = fedpg.monte_carlo(env, pol, scens[1].fedpg_config(),
                            jax.random.key(3), 2, ota=None)
    assert _hist_equal(ref, res.scenario_history(1))


def test_sweep_rejects_mesh_without_sharded(env_pol):
    env, pol = env_pol
    s = Scenario(channel=None, **SMALL)
    with pytest.raises(ValueError, match="mode='sharded'"):
        sweep(env, pol, [s], jax.random.key(0), 2, mesh=default_sweep_mesh())


# ---------------------------------------------------------------------------
# dispatch internals
# ---------------------------------------------------------------------------

def test_place_partition_reusable_for_benchmarks(env_pol):
    """place_partition(donate=False) returns a program benchmarks can call
    repeatedly on the same placed buffers (fig_scaling.py's timing loop)."""
    from repro.core.sweep import _make_lane, _pack_partition, partition_scenarios

    env, pol = env_pol
    scens = grid(channel=RayleighChannel(), noise_sigma=[1e-3, 1e-2], **SMALL)
    part = partition_scenarios(scens)[0]
    packed = _pack_partition(part)
    lane = _make_lane(env, pol, part)
    keys = jax.random.split(jax.random.key(0), 2)
    mesh = default_sweep_mesh()
    jitted, placed, keys_p, placement = place_partition(
        lane, packed, keys, mesh, donate=False)
    a = jitted(placed, keys_p)
    b = jitted(placed, keys_p)  # donate=False: same buffers, same result
    assert _hist_equal(a, b)
    assert placement.n_lanes == 2
    # and the one-shot dispatcher agrees
    c, _ = dispatch_partition(lane, packed, keys, mesh)
    assert _hist_equal(a, jax.tree.map(lambda x: x, c))


# ---------------------------------------------------------------------------
# agent-axis sharding: the production shard_map/psum round form
# ---------------------------------------------------------------------------

def test_agent_sharded_round_matches_stacked_deterministic(env_pol):
    """With a deterministic channel (FixedGain, sigma=0) the sharded and
    stacked forms see identical gains, so histories must agree to psum
    reassociation tolerance."""
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(n_agents=4, batch_m=2, horizon=6, n_rounds=4)
    ota = OTAConfig(channel=FixedGainChannel(gain=1.3), noise_sigma=0.0,
                    debias=True)
    mesh = agent_mesh_for(cfg.n_agents)
    _, h_ref = fedpg.run(env, pol, cfg, jax.random.key(1), ota=ota)
    _, h_sh = fedpg.run(env, pol, cfg, jax.random.key(1), ota=ota,
                        agent_mesh=mesh)
    for name, a, b in zip(("rewards", "grad_sq", "gain_mean"), h_ref, h_sh):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-6, err_msg=name)
    # exact uplink too (psum mean vs stacked mean)
    _, e_ref = fedpg.run(env, pol, cfg, jax.random.key(2))
    _, e_sh = fedpg.run(env, pol, cfg, jax.random.key(2), agent_mesh=mesh)
    np.testing.assert_allclose(np.asarray(e_ref.rewards),
                               np.asarray(e_sh.rewards), rtol=1e-4, atol=1e-6)
    assert np.all(np.asarray(e_sh.gain_mean) == 1.0)


def test_agent_sharded_heterogeneous_fleet():
    """Per-agent env stacks slice across shards: a sharded hetero fleet must
    match the vmapped fleet, and differ from a homogeneous run."""
    from repro.rl.envs import WindyLandmarkNav, make_heterogeneous_env

    n = 4
    het = make_heterogeneous_env(
        [WindyLandmarkNav(wind=0.05 * i, gust_sigma=0.0) for i in range(n)])
    cfg = fedpg.FedPGConfig(n_agents=n, batch_m=2, horizon=6, n_rounds=3)
    pol = het.default_policy()
    mesh = agent_mesh_for(n)
    _, h_ref = fedpg.run(het, pol, cfg, jax.random.key(0))
    _, h_sh = fedpg.run(het, pol, cfg, jax.random.key(0), agent_mesh=mesh)
    np.testing.assert_allclose(np.asarray(h_ref.rewards),
                               np.asarray(h_sh.rewards), rtol=1e-4, atol=1e-6)
    _, h_plain = fedpg.run(WindyLandmarkNav(wind=0.0, gust_sigma=0.0), pol,
                           cfg, jax.random.key(0))
    assert not np.allclose(np.asarray(h_sh.rewards),
                           np.asarray(h_plain.rewards))


def test_agent_sharded_heterogeneous_budget():
    """HeterogeneousBudget keys budgets on *global* agent indices, so the
    sharded per-agent power control must reproduce the stacked linspace."""
    env, pol = LandmarkNav(), MLPPolicy()
    cfg = fedpg.FedPGConfig(n_agents=4, batch_m=2, horizon=5, n_rounds=3)
    ota = OTAConfig(channel=FixedGainChannel(gain=1.0), noise_sigma=0.0,
                    power_control=HeterogeneousBudget(p_min=0.5, p_max=1.5))
    mesh = agent_mesh_for(cfg.n_agents)
    _, h_ref = fedpg.run(env, pol, cfg, jax.random.key(4), ota=ota)
    _, h_sh = fedpg.run(env, pol, cfg, jax.random.key(4), ota=ota,
                        agent_mesh=mesh)
    # unit base gain: mean effective gain == mean budget == 1.0 exactly
    np.testing.assert_allclose(np.asarray(h_sh.gain_mean), 1.0, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(h_ref.rewards),
                               np.asarray(h_sh.rewards), rtol=1e-4, atol=1e-6)


def test_agent_mesh_divisibility_guard(env_pol):
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(n_agents=3, batch_m=2, horizon=4, n_rounds=2)
    mesh = make_agent_mesh(1)
    # axis name must exist
    with pytest.raises(ValueError, match="no axis"):
        fedpg.make_round_fn(env, pol, cfg, None, agent_mesh=mesh,
                            agent_axis="nope")
    if N_DEV >= 2:
        bad = make_agent_mesh(2)  # 3 agents across 2 shards
        with pytest.raises(ValueError, match="does not divide"):
            fedpg.make_round_fn(env, pol, cfg, None, agent_mesh=bad)


# ---------------------------------------------------------------------------
# psum aggregation regression (jax<0.5 has no lax.axis_size — the shard_map
# forms must run anyway)
# ---------------------------------------------------------------------------

def _shard_grads(key, n_agents):
    ks = jax.random.split(key, 2)
    return {
        "w": jax.random.normal(ks[0], (n_agents, 3, 4), jnp.float32),
        "b": jax.random.normal(ks[1], (n_agents, 5), jnp.float32),
    }


def test_psum_aggregate_runs_on_axis_size_free_jax(key):
    """Regression: local_gain/psum_aggregate used jax.lax.axis_size, which
    the pinned jax doesn't have — the compat fallback must run on any mesh
    (here the whole-device agents mesh, degenerate size 1 included)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = make_agent_mesh()
    n = mesh.size
    g = _shard_grads(key, n)
    cfg = OTAConfig(channel=RayleighChannel(), noise_sigma=0.1, debias=True)
    round_key = jax.random.key(5)

    # each shard's block arrives (1, ...); drop the block axis so the local
    # grad is the shard's own pytree, as production shard_map code holds it
    out = shard_map(
        lambda gl: psum_aggregate(
            cfg, round_key, {k: v[0] for k, v in gl.items()}, ("agents",),
            n_agents=n),
        mesh=mesh, in_specs=({k: P("agents") for k in g},),
        out_specs={k: P() for k in g}, check_rep=False,
    )(g)

    key_h, _ = jax.random.split(round_key)
    gains = jnp.stack([cfg.channel.sample(jax.random.fold_in(key_h, i), ())
                       for i in range(n)])
    ref, _ = aggregate_stacked(cfg, round_key, g, gains=gains)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                                   atol=1e-6)


def test_psum_aggregate_stacked_local_agent_stacks(key):
    """The multi-agent-per-shard form: global gain indices are
    shard*n_local+j, so a 1-shard mesh with the full stack must equal
    aggregate_stacked fed the fold_in gain stream explicitly."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    n_agents = 6
    mesh = make_agent_mesh(1)
    g = _shard_grads(key, n_agents)
    cfg = OTAConfig(channel=RayleighChannel(), noise_sigma=0.05, debias=True,
                    power_control=HeterogeneousBudget(p_min=0.5, p_max=1.5))
    round_key = jax.random.key(6)

    def local(gl):
        upd, h = psum_aggregate_stacked(cfg, round_key, gl, ("agents",),
                                        n_agents=n_agents)
        return upd, h

    out, h = shard_map(
        local, mesh=mesh, in_specs=({k: P() for k in g},),
        out_specs=({k: P() for k in g}, P()), check_rep=False,
    )(g)
    assert h.shape == (n_agents,)

    key_h, _ = jax.random.split(round_key)

    def gain(i):
        c = cfg.channel.sample(jax.random.fold_in(key_h, i), ())
        return c * cfg.power_control.apply_indexed(
            c, jnp.asarray(i), n_agents)

    gains = jnp.stack([gain(i) for i in range(n_agents)])
    np.testing.assert_allclose(np.asarray(h), np.asarray(gains), rtol=1e-6)
    ref, _ = aggregate_stacked(cfg, round_key, g, gains=gains)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                                   atol=1e-6)
