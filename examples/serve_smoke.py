"""Serving example: prefill a prompt then decode with a batched KV cache,
including the sliding-window ring cache used for long-context serving.

    PYTHONPATH=src python examples/serve_smoke.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.configs.base import InputShape
from repro.models import model as model_lib
from repro.train import server


def main():
    cfg = get_smoke_config("internlm2-20b")
    model = model_lib.build(cfg)
    params = model.init(jax.random.key(0))

    b, prompt_len, gen = 4, 48, 32
    prompt = jax.random.randint(jax.random.key(1), (b, prompt_len), 0, cfg.vocab)

    # prefill fills the cache in one pass...
    logits, cache = model.prefill(params, prompt)
    # ...but serving uses a fixed-capacity cache; copy the prefill KV in.
    cap = prompt_len + gen
    full = model.init_cache(b, cap)
    full = full._replace(
        kv=jax.tree.map(
            lambda dst, src: jax.lax.dynamic_update_slice(
                dst, src, (0,) * dst.ndim
            ),
            full.kv, cache.kv,
        ),
        pos=jnp.asarray(prompt_len, jnp.int32),
    )

    shape = InputShape("serve", seq_len=cap, global_batch=b, kind="decode")
    step = jax.jit(server.make_serve_step(model, shape))

    tok = jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(gen - 1):
        tok, _, full = step(params, full, tok)
        out.append(tok)
    dt = time.time() - t0
    gen_tokens = jnp.concatenate(out, axis=1)
    print(f"generated {gen_tokens.shape} tokens in {dt:.2f}s "
          f"({b*(gen-1)/dt:.1f} tok/s on CPU)")
    print("first sequence:", gen_tokens[0].tolist())

    # long-context style: ring cache of capacity 32 (window serving)
    ring = model.init_cache(b, 32)
    ring = ring._replace(pos=jnp.asarray(500, jnp.int32))  # deep in a stream
    rstep = jax.jit(server.make_serve_step(
        model, InputShape("long", seq_len=10_000, global_batch=b, kind="decode")))
    tok2, _, ring = rstep(params, ring, tok)
    print(f"ring-cache decode at pos 500 with 32 slots -> next pos "
          f"{int(ring.pos)} OK")


if __name__ == "__main__":
    main()
