"""Sweep quickstart: a paper-style scenario grid as one declarative sweep.

Declares a {Rayleigh, Nakagami} x {noise} x {step size} grid over the
paper's landmark-navigation task and runs it through the batched
scenario-sweep engine — one compiled XLA program per channel family instead
of one per grid point — then prints the summary table the paper's figures
are built from.

    PYTHONPATH=src python examples/sweep_quickstart.py
"""
import jax

from repro.core.channel import NakagamiChannel, RayleighChannel
from repro.core.sweep import grid, sweep
from repro.rl.env import LandmarkNav
from repro.rl.policy import MLPPolicy


def main():
    env = LandmarkNav()
    policy = MLPPolicy(obs_dim=4, hidden=16, n_actions=5)  # the paper's net

    scenarios = grid(
        # channel family is a structural axis: one compiled program each
        channel=[RayleighChannel(), NakagamiChannel(m=0.1, omega=1.0)],
        # noise level and step size are continuous axes: batched in-program
        noise_sigma=[1e-3, 1e-2],
        alpha=[5e-3, 1e-3],
        n_agents=10, batch_m=10, horizon=20, n_rounds=60, debias=True,
    )
    print(f"{len(scenarios)} scenarios")

    result = sweep(env, policy, scenarios, jax.random.key(0), mc_runs=3)
    print(f"compiled programs: {result.n_compiles} "
          f"(vs {len(scenarios)} for a per-scenario loop)")
    print()
    tail = 10
    print(result.to_csv(tail=tail))

    best = max(range(len(result)), key=lambda i: result.final_reward(i, tail))
    s = result.scenarios[best]
    print(f"best final reward: scenario {best} "
          f"({type(s.channel).__name__}, noise={s.noise_sigma:g}, "
          f"alpha={s.alpha:g}) -> {result.final_reward(best, tail):.3f}")


if __name__ == "__main__":
    main()
