"""Quickstart: the paper's algorithm in ~40 lines.

Runs Algorithm 2 (over-the-air federated policy gradient) on the paper's
landmark-navigation task with a Rayleigh fading channel, and compares it to
Algorithm 1 (exact aggregation).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import fedpg
from repro.core.channel import make_channel, noise_sigma_from_db
from repro.core.ota import OTAConfig
from repro.rl.env import LandmarkNav
from repro.rl.policy import MLPPolicy


def main():
    env = LandmarkNav()
    policy = MLPPolicy(obs_dim=4, hidden=16, n_actions=5)  # the paper's net

    cfg = fedpg.FedPGConfig(
        n_agents=10,       # N
        batch_m=10,        # M
        horizon=20,        # T  (paper, Section IV)
        gamma=0.99,
        alpha=5e-3,
        n_rounds=300,      # K
    )

    # Algorithm 2: over-the-air aggregation through a Rayleigh channel with
    # sigma^2 = -60 dB receiver noise (the paper's setting).
    ota = OTAConfig(
        channel=make_channel("rayleigh"),
        noise_sigma=noise_sigma_from_db(-60.0),
        debias=True,
    )

    print("running Algorithm 2 (OTA, Rayleigh)...")
    _, h_ota = fedpg.run_jit(env, policy, cfg, jax.random.key(0), ota=ota)
    print("running Algorithm 1 (exact uplink)...")
    _, h_exact = fedpg.run_jit(env, policy, cfg, jax.random.key(0))

    for name, h in [("OTA", h_ota), ("exact", h_exact)]:
        r0 = float(jnp.mean(h.rewards[:20]))
        r1 = float(jnp.mean(h.rewards[-20:]))
        gsq = float(jnp.mean(h.grad_sq))
        print(f"  {name:6s} reward {r0:7.3f} -> {r1:7.3f}   "
              f"(1/K) sum ||grad J||^2 = {gsq:.4f}")
    print("OTA converges at the same order as the exact uplink (paper Fig. 3)"
          " while using a single shared channel use per round.")


if __name__ == "__main__":
    main()
