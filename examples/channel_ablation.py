"""Channel ablation: how fading statistics shape convergence (Theorems 1/2).

Sweeps channels with increasing gain variance at fixed mean — Rayleigh
(sigma_h^2 ~ 0.27 m_h^2), Nakagami m=0.5 (2 m_h^2), Nakagami m=0.1
(10 m_h^2) — plus power-controlled truncated inversion, and prints the
empirical (1/K) sum ||grad J||^2 next to the Theorem-2 prediction's channel
floor, reproducing the paper's Rayleigh-vs-Nakagami contrast (Figs. 1 vs 4).

    PYTHONPATH=src python examples/channel_ablation.py
"""
import jax
import jax.numpy as jnp

from repro.core import fedpg, theory
from repro.core.channel import (
    NakagamiChannel, RayleighChannel, noise_sigma_from_db,
)
from repro.core.ota import OTAConfig
from repro.core.power_control import (
    TruncatedInversion, make_controlled_channel,
)
from repro.rl.env import LandmarkNav
from repro.rl.policy import MLPPolicy


def main():
    env, pol = LandmarkNav(), MLPPolicy()
    n_agents, batch_m, rounds = 10, 5, 250
    sigma = noise_sigma_from_db(-60.0)

    channels = {
        "rayleigh": RayleighChannel(),
        "nakagami m=0.5": NakagamiChannel(m=0.5, omega=1.0),
        "nakagami m=0.1": NakagamiChannel(m=0.1, omega=1.0),
        "rayleigh + trunc-inversion": make_controlled_channel(
            RayleighChannel(), TruncatedInversion(target=1.0, p_max=5.0,
                                                  c_min=0.2),
            jax.random.key(99),
        ),
    }

    print(f"{'channel':28s} {'var/mean^2':>10s} {'thm1 ok(N=10)':>13s} "
          f"{'reward':>8s} {'avg||gJ||^2':>12s}")
    for name, ch in channels.items():
        cfg = fedpg.FedPGConfig(
            n_agents=n_agents, batch_m=batch_m, n_rounds=rounds,
            alpha=1e-3 if ch.var > ch.mean**2 else 5e-3,
        )
        ota = OTAConfig(channel=ch, noise_sigma=sigma, debias=True)
        _, hist = fedpg.run_jit(env, pol, cfg, jax.random.key(0), ota=ota)
        ratio = ch.var / ch.mean**2
        ok = theory.channel_condition_ok(n_agents, ch.mean, ch.var)
        rew = float(jnp.mean(hist.rewards[-20:]))
        gsq = float(jnp.mean(hist.grad_sq))
        print(f"{name:28s} {ratio:10.2f} {str(ok):>13s} {rew:8.3f} {gsq:12.4f}")
    print("\nhigher gain variance (smaller Nakagami m) => worse convergence "
          "(paper Fig. 4); power control tames the tail.")


if __name__ == "__main__":
    main()
