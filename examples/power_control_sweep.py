"""Power control as a sweep axis: how transmit policies move the channel.

Sweeps transmit-power policies (and a policy-parameter axis) over the
Rayleigh channel in one declarative grid, then prints the effective
(m_h, sigma_h^2) each policy realises next to the Theorem-1/2 variance
floor evaluated at those effective moments — the "power control moves the
channel-variance floor" story from the OTA-FL literature.

Policy *type* is a structural axis (one compiled program each); policy
*parameters* (here the inversion target) batch inside one program via the
registered ``ControlledChannel`` packing.

    PYTHONPATH=src python examples/power_control_sweep.py
"""
import jax

from repro.core import theory
from repro.core.channel import RayleighChannel
from repro.core.power_control import (
    ConstantReceived, TruncatedInversion, make_controlled_channel,
)
from repro.core.sweep import grid, sweep
from repro.rl.env import LandmarkNav
from repro.rl.policy import MLPPolicy


def main():
    env = LandmarkNav()
    policy = MLPPolicy(obs_dim=4, hidden=16, n_actions=5)

    base = RayleighChannel()
    channels = [
        base,  # no power control: h = c
        # inversion-target axis: one ControlledChannel per target, all
        # batching into a single compiled program
        *[make_controlled_channel(base, TruncatedInversion(target=t))
          for t in (0.8, 1.0, 1.2)],
        make_controlled_channel(base, ConstantReceived(target=1.0)),
    ]
    scenarios = grid(
        channel=channels,
        noise_sigma=1e-3,
        alpha=5e-3,
        n_agents=10, batch_m=10, horizon=20, n_rounds=60, debias=True,
    )
    result = sweep(env, policy, scenarios, jax.random.key(0), mc_runs=3)
    print(f"{len(scenarios)} scenarios in {result.n_compiles} compiled "
          "programs\n")

    print(f"{'channel':44s} {'m_h_eff':>8s} {'s_h2_eff':>9s} "
          f"{'floor':>9s} {'final_reward':>13s}")
    rows = result.to_dicts(tail=10)
    for i, s in enumerate(result.scenarios):
        m_h, v_h = s.effective_moments()
        floor = theory.theorem1_floor(
            n_agents=s.n_agents, batch_m=s.batch_m, m_h=m_h, sigma_h2=v_h,
            noise_sigma2=s.noise_sigma**2, V=5.0,
        )
        print(f"{rows[i]['channel'][:44]:44s} {m_h:8.4f} {v_h:9.5f} "
              f"{floor:9.5f} {result.final_reward(i, 10):13.3f}")


if __name__ == "__main__":
    main()
