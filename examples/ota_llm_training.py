"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps with OTA gradient aggregation as the data-parallel reduction.

This is the paper's technique transplanted to the LLM stack: each of the
``--n-agents`` data-parallel groups is an "agent"; per-agent Rayleigh gains
are folded into the loss weights (exactly sum_i h_i g_i / N) and the server
AWGN is added to the aggregated gradient each step.

    PYTHONPATH=src python examples/ota_llm_training.py [--steps 300]
"""
import argparse
import time

import jax

from repro.configs import get_smoke_config
from repro.configs.base import InputShape
from repro.data.pipeline import make_batch
from repro.models import model as model_lib
from repro.train import trainer
from repro.utils.tree import tree_size


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--n-agents", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "xla", "pallas"),
                    help="uplink implementation: the fused Pallas kernel, "
                         "the XLA op chain, or auto (pallas on TPU)")
    ap.add_argument("--wire-dtype", default="",
                    choices=("", "bfloat16"),
                    help="uplink payload dtype on the pallas backend "
                         "(fp32 master copy either way)")
    args = ap.parse_args()

    # ~100M params: llama3.2-3b family, reduced width/depth
    cfg = get_smoke_config("llama3.2-3b").with_(
        n_layers=4, d_model=512, n_heads=8, n_kv_heads=4, d_ff=1536,
        vocab=32768,
    )
    model = model_lib.build(cfg)
    n_params = tree_size(model.abstract())
    print(f"model: {cfg.arch_id}-smoke, {n_params/1e6:.1f}M params")

    shape = InputShape("ex", seq_len=args.seq_len, global_batch=args.batch,
                       kind="train")
    tcfg = trainer.TrainConfig(
        aggregator="ota", channel="rayleigh", noise_db=-60.0,
        n_agents=args.n_agents, microbatch=2, lr=1e-3,
        warmup=20, total_steps=args.steps,
        ota_backend=args.backend, wire_dtype=args.wire_dtype,
    )
    state = trainer.init_state(model, tcfg, jax.random.key(0))
    step = jax.jit(trainer.make_train_step(model, tcfg))
    key = jax.random.key(1)

    t0, losses = time.time(), []
    for i in range(args.steps):
        batch = make_batch(cfg, shape, i)
        state, metrics = step(state, batch, key)
        losses.append(float(metrics["loss"]))
        if i % 25 == 0:
            print(f"step {i:4d}  loss {losses[-1]:.4f}  "
                  f"gain {float(metrics['gain_mean']):.3f}  "
                  f"({time.time()-t0:.1f}s)")
    print(f"final loss {sum(losses[-10:])/10:.4f} "
          f"(from {sum(losses[:10])/10:.4f}); "
          f"{args.steps/(time.time()-t0):.2f} steps/s")


if __name__ == "__main__":
    main()
