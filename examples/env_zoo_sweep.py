"""Environment-zoo quickstart: the workload as a sweep axis.

Three things the env registry buys on top of the channel/power sweeps:

1. `grid(env=[...])` — env families partition structurally (one compiled
   program each), same-family continuous parameters (here: wind strength)
   batch as lanes inside ONE program;
2. heterogeneous agents — a `HeterogeneousEnv` fleet gives every federated
   agent its own dynamics (per-agent wind), vmapped inside the same jitted
   round body;
3. policies resolve per family through the registry (`default_policy`):
   the discrete landmark tasks get the paper's MLP, CliffWalk a tabular
   softmax — no manual wiring.

    PYTHONPATH=src python examples/env_zoo_sweep.py
"""
import jax

from repro.core.channel import RayleighChannel
from repro.core.sweep import grid, sweep
from repro.rl.envs import CliffWalk, WindyLandmarkNav, make_heterogeneous_env


def main():
    fleet = make_heterogeneous_env(
        [WindyLandmarkNav(wind=0.03 * i) for i in range(4)]
    )

    scenarios = grid(
        # env family is structural; the wind parameter batches as lanes
        env=[
            WindyLandmarkNav(wind=0.0),
            WindyLandmarkNav(wind=0.08),
            CliffWalk(width=5, height=3, slip=0.1),
            fleet,                      # per-agent heterogeneous dynamics
        ],
        channel=[None, RayleighChannel()],  # exact vs over-the-air uplink
        noise_sigma=1e-3,
        n_agents=4, batch_m=4, horizon=10, n_rounds=60, debias=True,
    )
    print(f"{len(scenarios)} scenarios")

    result = sweep(None, None, scenarios, jax.random.key(0), mc_runs=3)
    print(f"compiled programs: {result.n_compiles} "
          f"(vs {len(scenarios)} for a per-scenario loop — the two wind "
          f"lanes share one program per uplink)")
    print()
    print(result.to_csv(tail=10))

    i = result.index(env=fleet, channel=None)
    print(f"heterogeneous fleet (exact uplink) final reward: "
          f"{result.final_reward(i, tail=10):.3f}")


if __name__ == "__main__":
    main()
