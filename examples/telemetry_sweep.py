"""Observability demo: an instrumented Rayleigh sweep, end to end.

Runs a small {noise} x {step size} Rayleigh grid with in-jit telemetry
probes on, then writes the full observability artifact set:

* ``TRACE_sweep.json``  — Chrome trace-event JSON of the per-partition
  compile/execute spans (open in Perfetto or ``chrome://tracing``);
* ``LEDGER.jsonl``      — the JSONL run ledger: platform, compile counts,
  one record per scenario with the measured ``avg_grad_sq`` next to its
  Theorem-1/2 noise floor and the probe summaries (effective SNR,
  channel-moment drift, grad-norm dispersion);
* ``REPORT.md``         — the ledger rendered as markdown
  (``python -m repro.telemetry.report LEDGER.jsonl`` does the same).

    PYTHONPATH=src python examples/telemetry_sweep.py [--outdir DIR]
"""
import argparse
import math
import os

import jax

from repro.core import theory
from repro.core.channel import RayleighChannel
from repro.core.sweep import grid, sweep
from repro.rl.env import LandmarkNav
from repro.rl.policy import MLPPolicy
from repro.telemetry import Ledger, TelemetryConfig, trace as rtrace
from repro.telemetry.report import render
from repro.telemetry.ledger import read_ledger


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default=".")
    ap.add_argument("--mc-runs", type=int, default=2)
    args = ap.parse_args()

    env = LandmarkNav()
    policy = MLPPolicy(obs_dim=4, hidden=16, n_actions=5)
    scenarios = grid(
        channel=[RayleighChannel()],
        noise_sigma=[1e-3, 1e-2, 1e-1],
        alpha=[5e-3, 1e-3],
        n_agents=10, batch_m=10, horizon=20, n_rounds=40, debias=True,
    )
    # the surrogate MDP constants the theory tables use (G, F, l_bar, gamma)
    consts = theory.MDPConstants(G=math.sqrt(2.0), F=0.5, l_bar=1.0,
                                 gamma=0.9)

    trace_path = os.path.join(args.outdir, "TRACE_sweep.json")
    ledger_path = os.path.join(args.outdir, "LEDGER.jsonl")
    report_path = os.path.join(args.outdir, "REPORT.md")

    rtrace.reset()
    with Ledger(ledger_path) as led:
        led.log_platform()
        with led.count_compiles(label="telemetry_sweep"):
            result = sweep(env, policy, scenarios, jax.random.key(0),
                           args.mc_runs, telemetry=TelemetryConfig())
        led.log_sweep(result, constants=consts, label="rayleigh_grid")
    rtrace.export(trace_path)

    text = render(read_ledger(ledger_path), title="Telemetry sweep")
    with open(report_path, "w", encoding="utf-8") as f:
        f.write(text)

    print(text)
    print(f"wrote {trace_path}, {ledger_path}, {report_path}")


if __name__ == "__main__":
    main()
